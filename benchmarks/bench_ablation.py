"""Paper Fig. 8–10: ablations of the two VDTuner components —
successive abandon (vs round-robin) and the NPI polling surrogate (vs a
native GP on raw objectives).

All variants are plain ask/tell recommenders driven by the one
``TuningSession`` harness; the per-variant ``session`` block reports the
recommend/eval ledger (stable schema)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import TuningSession, VDTuner

from repro.vdms import make_space

from .common import N_ITERS, RECALL_FLOORS, emit, make_env


class VDTunerNoAbandon(VDTuner):
    """Round-robin polling: the abandon trigger never fires."""

    name = "vdtuner_rr"

    def __init__(self, *a, **kw):
        kw["abandon_window"] = 10**9
        super().__init__(*a, **kw)


class VDTunerNativeGP(VDTuner):
    """Native surrogate: GP trained on raw (max-normalized) objectives instead
    of the per-index-type NPI normalization."""

    name = "vdtuner_native"

    def ask(self, n: int = 1):
        import repro.core.tuner as tuner_mod

        orig = tuner_mod.npi_normalize

        def raw_normalize(Y, types, mode="balanced"):
            ymax = Y.max(axis=0)
            ymax = np.where(ymax <= 0, 1.0, ymax)
            bases = {str(t): ymax for t in np.unique(types)}
            return Y / ymax[None, :], bases

        tuner_mod.npi_normalize = raw_normalize
        try:
            return super().ask(n)
        finally:
            tuner_mod.npi_normalize = orig


def run(seed: int = 0, dataset: str = "glove_like"):
    space = make_space()
    env = make_env(dataset, seed=seed)
    out = {}
    for name, cls in (
        ("vdtuner", VDTuner),
        ("round_robin", VDTunerNoAbandon),
        ("native_gp", VDTunerNativeGP),
    ):
        t = cls(space, env, seed=seed)
        session = TuningSession(t)
        t0 = time.perf_counter()
        session.run(N_ITERS)
        wall = time.perf_counter() - t0
        floors = {r: t.best_speed_at_recall(r) for r in RECALL_FLOORS}
        out[name] = {
            "speed_at_floor": floors,
            "abandoned": list(getattr(t.abandon, "abandoned", [])),
            "score_log_len": len(t.abandon.score_log),
            "session": session.ledger_dict(),
        }
        emit(
            f"ablation/{dataset}/{name}", wall * 1e6 / N_ITERS,
            ";".join(f"r{r}={floors[r]:.0f}" if np.isfinite(floors[r]) else f"r{r}=nan"
                     for r in (0.85, 0.95, 0.99)),
        )
    # Fig. 9 analogue: the dynamic score trajectory of the full tuner
    return out


if __name__ == "__main__":
    print(run())
