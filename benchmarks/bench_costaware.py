"""Paper Fig. 13: cost-effectiveness optimization — QP$ = QPS / (eta * GiB)
as the speed objective, compared with plain QPS optimization."""
from __future__ import annotations

import time

import numpy as np

from repro.core import VDTuner, cost_aware_transform
from repro.vdms import make_space

from .common import N_ITERS, emit, make_env


def run(seed: int = 0, dataset: str = "georadius_like"):
    space = make_space()
    env = make_env(dataset, seed=seed)
    t0 = time.perf_counter()
    qps_opt = VDTuner(space, env, seed=seed).run(N_ITERS)
    w0 = time.perf_counter() - t0
    t0 = time.perf_counter()
    qpd_opt = VDTuner(space, env, seed=seed, transform=cost_aware_transform(1.0)).run(N_ITERS)
    w1 = time.perf_counter() - t0

    def stats(tuner):
        mems = np.array([o.raw.get("mem_gib", np.nan) for o in tuner.history if not o.failed])
        speeds = np.array([o.raw.get("speed", np.nan) for o in tuner.history if not o.failed])
        qpd = speeds / np.maximum(mems, 1e-9)
        return {
            "mem_mean": float(np.nanmean(mems)), "mem_std": float(np.nanstd(mems)),
            "best_qps": float(np.nanmax(speeds)), "best_qpd": float(np.nanmax(qpd)),
        }

    s_qps, s_qpd = stats(qps_opt), stats(qpd_opt)
    out = {"optimize_qps": s_qps, "optimize_qpd": s_qpd}
    emit("costaware/qps", w0 * 1e6 / N_ITERS,
         f"best_qps={s_qps['best_qps']:.0f};mem={s_qps['mem_mean']:.4f}GiB")
    emit("costaware/qpd", w1 * 1e6 / N_ITERS,
         f"best_qpd={s_qpd['best_qpd']:.0f};mem={s_qpd['mem_mean']:.4f}GiB;"
         f"qpd_gain={(s_qpd['best_qpd']/s_qps['best_qpd']-1)*100:.1f}%")
    return out


if __name__ == "__main__":
    print(run())
