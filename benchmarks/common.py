"""Shared benchmark harness utilities."""
from __future__ import annotations

import os
import time
from typing import Optional

import numpy as np

from repro.core import (
    DefaultOnly, OpenTunerLike, OtterTuneLike, QEHVI, RandomLHS, TuningSession, VDTuner,
    hv_2d, pareto_front,
)
from repro.vdms import VDMSTuningEnv, make_dataset

# benchmark scale knobs (FULL=1 reproduces paper-scale runs)
FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))
N_VECTORS = 32768 if FULL else 6144
N_ITERS = 200 if FULL else 36
MODE = "wall" if FULL else "analytic"
DATASETS = ("glove_like", "keyword_like", "georadius_like")
RECALL_FLOORS = (0.85, 0.875, 0.9, 0.925, 0.95, 0.975, 0.99)


def make_env(dataset: str, seed: int = 0, mode: Optional[str] = None,
             n: Optional[int] = None) -> VDMSTuningEnv:
    n = n or N_VECTORS
    dim = None
    if dataset == "georadius_like":
        n = max(n // 4, 2048)
    ds = make_dataset(dataset, n=n, n_queries=128, k=10, seed=seed, dim=dim)
    return VDMSTuningEnv(ds, mode=mode or MODE, seed=seed)


def run_method(name: str, env, space, n_iters: int, seed: int = 0, executor=None, **kw):
    """Drive any tuner through the one ``TuningSession`` harness.

    Returns ``(tuner, wall_s, session)`` — the session carries the
    per-iteration recommend/eval ledger (``session.ledger_dict()``).
    """
    cls = {
        "vdtuner": VDTuner, "default": DefaultOnly, "random_lhs": RandomLHS,
        "ottertune": OtterTuneLike, "qehvi": QEHVI, "opentuner": OpenTunerLike,
    }[name]
    t0 = time.perf_counter()
    tuner = cls(space, env, seed=seed, **kw)
    session = TuningSession(tuner, executor=executor)
    session.run(n_iters)
    wall = time.perf_counter() - t0
    return tuner, wall, session


def norm_hv(tuner, ymax) -> float:
    return hv_2d(pareto_front(tuner.Y) / np.asarray(ymax), np.zeros(2))


def emit(name: str, us_per_call: float, derived: str):
    """CSV row in the required ``name,us_per_call,derived`` format."""
    print(f"{name},{us_per_call:.2f},{derived}")
