"""Paper Fig. 12: user recall-rate preference — constraint model (CEI) and
bootstrapping from a previous constraint level."""
from __future__ import annotations

import time

import numpy as np

from repro.core import VDTuner
from repro.vdms import make_space

from .common import N_ITERS, emit, make_env


def best_feasible_speed(tuner, rlim):
    return tuner.best_speed_at_recall(rlim)


def iters_to_speed(tuner, rlim, target):
    best = -np.inf
    for o in tuner.history:
        if o.bootstrap:
            continue
        if not o.failed and o.y[1] >= rlim:
            best = max(best, o.y[0])
        if best >= target:
            return o.iteration + 1
    return None


def run(seed: int = 0, dataset: str = "glove_like"):
    space = make_space()
    out = {}
    # phase 1: rlim = 0.85
    env = make_env(dataset, seed=seed)
    t0 = time.perf_counter()
    no_constraint = VDTuner(space, env, seed=seed).run(N_ITERS)
    w0 = time.perf_counter() - t0
    t0 = time.perf_counter()
    with_constraint = VDTuner(space, env, seed=seed + 1, rlim=0.85).run(N_ITERS)
    w1 = time.perf_counter() - t0
    target = best_feasible_speed(no_constraint, 0.85)
    out["rlim_0.85"] = {
        "no_constraint_best": target,
        "constraint_best": best_feasible_speed(with_constraint, 0.85),
        "constraint_iters_to_match": iters_to_speed(with_constraint, 0.85, target),
    }
    # phase 2: rlim = 0.9 — with and without bootstrapping from phase 1
    t0 = time.perf_counter()
    cold = VDTuner(space, env, seed=seed + 2, rlim=0.9).run(N_ITERS)
    w2 = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = VDTuner(
        space, env, seed=seed + 3, rlim=0.9,
        bootstrap_history=with_constraint.history,
    ).run(N_ITERS)
    w3 = time.perf_counter() - t0
    target9 = best_feasible_speed(cold, 0.9)
    out["rlim_0.9"] = {
        "cold_best": target9,
        "warm_best": best_feasible_speed(warm, 0.9),
        "cold_iters_to_best": iters_to_speed(cold, 0.9, target9),
        "warm_iters_to_match_cold": iters_to_speed(warm, 0.9, target9),
    }
    emit("preference/constraint_0.85", w1 * 1e6 / N_ITERS,
         f"best={out['rlim_0.85']['constraint_best']:.0f};"
         f"match_iters={out['rlim_0.85']['constraint_iters_to_match']}")
    emit("preference/bootstrap_0.9", w3 * 1e6 / N_ITERS,
         f"warm_best={out['rlim_0.9']['warm_best']:.0f};"
         f"warm_match={out['rlim_0.9']['warm_iters_to_match_cold']};"
         f"cold_best={out['rlim_0.9']['cold_best']:.0f}")
    return out


if __name__ == "__main__":
    print(run())
