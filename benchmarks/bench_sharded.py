"""Sharded multi-device segment serving at realistic corpus size.

Builds one IVF_SQ8 corpus at ``n_base >= 1M`` (256+ sealed segments), places
it at increasing shard counts with :class:`~repro.vdms.sharded.ShardedVDMS`,
and measures per shard count:

* **QPS** in the deterministic analytic mode (the CI-gated number: leaf work
  charges the critical shard, the root merge charges the shard count) with
  wall-clock reported alongside;
* **recall** against the brute-force oracle — gated to match the unsharded
  engine *exactly* (sharding must never change what is returned);
* **(gid, score) result sets** — gated identical across every shard count;
* a **Poisson multi-stream replay** (``repro.vdms.replay_query_streams``)
  offered at ~70% of the measured analytic capacity: served QPS, sojourn
  percentiles, utilization, saturation flag.

``--check-invariants`` exits non-zero unless the recall/result-set
invariants hold AND the 1→4-shard analytic scaling clears
``MIN_QPS_SCALING_1_TO_4`` (when a 4-shard point is in the run). CI runs the
quick mode on a 4-device host-emulated mesh (``sharded-smoke``) and uploads
``BENCH_sharded.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.vdms import (
    ShardedVDMS,
    VDMSInstance,
    make_dataset,
    recall_at_k,
    replay_query_streams,
)
from repro.vdms.sharded import MIN_QPS_SCALING_1_TO_4

from .common import emit

SHARD_COUNTS = (1, 2, 4)


def _sizes(quick: bool):
    if quick:
        return dict(n_base=1_048_576, dim=64, n_queries=64, k=10)
    return dict(n_base=4_194_304, dim=64, n_queries=256, k=10)


def _config(quick: bool):
    return dict(
        index_type="IVF_SQ8",
        nlist=64,
        nprobe=8,
        kmeans_iters=4,
        segment_max_size=4096,
        seal_proportion=1.0,
        search_batch_size=32,
        graceful_time=0.2,
        topk_merge_width=32,
        storage_bf16=False,
    )


def _result_set(ids: np.ndarray, scores: np.ndarray):
    """Per-query frozenset of (gid, score-bits) — the shard-count invariant
    compares exact float bit patterns, not approximate equality."""
    bits = scores.view(np.int32)
    return [
        frozenset(
            (int(g), int(b)) for g, b in zip(row_i, row_b) if g >= 0
        )
        for row_i, row_b in zip(ids, bits)
    ]


def run(seed: int = 0, quick: bool = True, shard_counts=SHARD_COUNTS):
    sz = _sizes(quick)
    t0 = time.perf_counter()
    ds = make_dataset(
        "glove_like", n=sz["n_base"], n_queries=sz["n_queries"],
        dim=sz["dim"], k=sz["k"], seed=seed,
    )
    dataset_s = time.perf_counter() - t0
    cfg = _config(quick)
    t0 = time.perf_counter()
    inst = VDMSInstance(ds, cfg, seed=seed)
    build_s = time.perf_counter() - t0

    n_devices = len(jax.devices())
    out = {
        "n_base": sz["n_base"],
        "dim": sz["dim"],
        "n_queries": sz["n_queries"],
        "k": sz["k"],
        "n_sealed": int(inst.plan.n_sealed),
        "n_devices": n_devices,
        "dataset_s": dataset_s,
        "build_s": build_s,
        "min_qps_scaling_1_to_4": MIN_QPS_SCALING_1_TO_4,
        "shards": {},
    }

    baseline = None
    for n in shard_counts:
        sharded = ShardedVDMS.from_instance(inst, n_shards=n)
        # one compiled warm pass, then the scored searches
        ids, scores, _ = sharded.search(
            ds.queries, sz["k"], mode="analytic", return_scores=True
        )
        _, analytic_s = sharded.search(ds.queries, sz["k"], mode="analytic")
        _, wall_s = sharded.search(ds.queries, sz["k"], mode="wall")
        qps = sz["n_queries"] / max(analytic_s, 1e-12)
        recall = float(recall_at_k(ids[:, : ds.k], ds.ground_truth))
        rec = {
            "dispatch": sharded.dispatch,
            "qps_analytic": float(qps),
            "qps_wall": float(sz["n_queries"] / max(wall_s, 1e-12)),
            "recall": recall,
            "mem_gib": float(sharded.memory_gib()),
            "stats": sharded.stats(),
        }
        if baseline is None:
            baseline = {
                "qps": qps,
                "recall": recall,
                "sets": _result_set(ids, scores),
                "ids": ids,
            }
            rec["qps_scaling_vs_1"] = 1.0
            rec["recall_matches_unsharded"] = True
            rec["result_sets_match"] = True
            rec["bitwise_identical"] = True
        else:
            rec["qps_scaling_vs_1"] = float(qps / baseline["qps"])
            rec["recall_matches_unsharded"] = bool(recall == baseline["recall"])
            rec["result_sets_match"] = bool(
                _result_set(ids, scores) == baseline["sets"]
            )
            rec["bitwise_identical"] = bool(np.array_equal(ids, baseline["ids"]))
        # Poisson multi-stream replay at ~70% of analytic capacity
        rec["poisson"] = replay_query_streams(
            sharded, ds.queries, rate=0.7 * qps, n_streams=8,
            n_per_stream=32 if quick else 64, topk=sz["k"], seed=seed,
        )
        out["shards"][str(n)] = rec
        emit(
            f"sharded/{n}",
            analytic_s / sz["n_queries"] * 1e6,
            f"qps={qps:.0f};scale={rec['qps_scaling_vs_1']:.2f};"
            f"recall={recall:.3f};dispatch={sharded.dispatch}",
        )
    return out


def check_invariants(out) -> list:
    """The CI gate: returns a list of violation strings (empty = pass)."""
    bad = []
    for n, rec in out["shards"].items():
        if not rec["recall_matches_unsharded"]:
            bad.append(f"{n} shards: recall diverged from the unsharded engine")
        if not rec["result_sets_match"]:
            bad.append(f"{n} shards: (gid, score) result sets changed")
    rec4 = out["shards"].get("4")
    if rec4 is not None:
        if rec4["qps_scaling_vs_1"] < out["min_qps_scaling_1_to_4"]:
            bad.append(
                f"1->4 shard scaling {rec4['qps_scaling_vs_1']:.2f}x below the "
                f"{out['min_qps_scaling_1_to_4']}x gate"
            )
    if out["n_base"] < 1_000_000:
        bad.append(f"n_base={out['n_base']} below the 1M-vector floor")
    return bad


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI-sized corpus (1M vectors)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument(
        "--shards", nargs="+", type=int, default=list(SHARD_COUNTS),
        help="shard counts to measure (dispatch falls back to vmap beyond the device count)",
    )
    p.add_argument("--json", default=None, metavar="PATH", help="write results as JSON (CI artifact)")
    p.add_argument(
        "--check-invariants", action="store_true",
        help="exit 1 unless recall/result-set invariants hold and 1->4 "
             "scaling clears the gate",
    )
    args = p.parse_args(argv)

    out = run(seed=args.seed, quick=args.quick, shard_counts=tuple(args.shards))
    violations = check_invariants(out)
    out["invariant_violations"] = violations
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)

    for n, rec in out["shards"].items():
        po = rec["poisson"]
        print(
            f"{n} shards ({rec['dispatch']}): qps={rec['qps_analytic']:.0f} "
            f"(scale {rec['qps_scaling_vs_1']:.2f}x) recall={rec['recall']:.3f} "
            f"poisson served={po['served_qps']:.0f}/{po['offered_qps']:.0f} "
            f"p99={po['sojourn_p99_s'] * 1e3:.2f}ms util={po['utilization']:.2f}"
        )
    if violations:
        for v in violations:
            print(f"INVARIANT VIOLATION: {v}", file=sys.stderr)
    return 1 if (args.check_invariants and violations) else 0


if __name__ == "__main__":
    sys.exit(main())
