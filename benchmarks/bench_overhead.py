"""Paper Table VI: tuning-time breakdown (configuration recommendation vs
workload replay) per method."""
from __future__ import annotations


from repro.vdms import make_space

from .common import N_ITERS, emit, make_env, run_method

METHODS = ("vdtuner", "random_lhs", "ottertune", "qehvi", "opentuner")


def run(seed: int = 0, dataset: str = "glove_like"):
    space = make_space()
    out = {}
    for m in METHODS:
        env = make_env(dataset, seed=seed)
        tuner, wall, session = run_method(m, env, space, N_ITERS, seed=seed)
        rec = sum(o.recommend_time for o in tuner.history)
        replay = sum(o.eval_time for o in tuner.history)
        out[m] = {
            "recommend_s": rec, "replay_s": replay, "total_s": wall,
            "recommend_pct": 100 * rec / max(wall, 1e-9),
            "session": session.ledger_dict(),
        }
        emit(f"overhead/{m}", wall * 1e6 / N_ITERS,
             f"rec={rec:.1f}s({100*rec/max(wall,1e-9):.2f}%);replay={replay:.1f}s")
    return out


if __name__ == "__main__":
    print(run())
