"""Recommendation overhead benchmarks.

Two views:

* ``run()`` — paper Table VI: tuning-time breakdown (configuration
  recommendation vs workload replay) per method, driven end-to-end.
* ``run_ask_overhead()`` — per-iteration ``ask()`` time of the numpy
  reference path vs the device-resident fused engine (warm-started GP
  refits) at a fixed history size, for q ∈ {1, 4, 8}. Emits
  ``BENCH_overhead.json``; the CI smoke job gates the fused path's
  recommend_time per iteration against a checked-in baseline
  (``benchmarks/baselines/overhead_ci.json``).

CLI::

    python -m benchmarks.bench_overhead                 # ask-overhead bench
    python -m benchmarks.bench_overhead --quick         # CI-sized budget
    python -m benchmarks.bench_overhead --check-speedup # assert >= 3x at q=4
    python -m benchmarks.bench_overhead --check-against benchmarks/baselines/overhead_ci.json
    python -m benchmarks.bench_overhead --table-vi      # the paper table
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core import VDTuner
from repro.vdms import make_space

from .common import N_ITERS, emit, make_env, run_method

METHODS = ("vdtuner", "random_lhs", "ottertune", "qehvi", "opentuner")


def run(seed: int = 0, dataset: str = "glove_like"):
    space = make_space()
    out = {}
    for m in METHODS:
        env = make_env(dataset, seed=seed)
        tuner, wall, session = run_method(m, env, space, N_ITERS, seed=seed)
        rec = sum(o.recommend_time for o in tuner.history)
        replay = sum(o.eval_time for o in tuner.history)
        out[m] = {
            "recommend_s": rec, "replay_s": replay, "total_s": wall,
            "recommend_pct": 100 * rec / max(wall, 1e-9),
            "session": session.ledger_dict(),
        }
        emit(f"overhead/{m}", wall * 1e6 / N_ITERS,
             f"rec={rec:.1f}s({100*rec/max(wall,1e-9):.2f}%);replay={replay:.1f}s")
    return out


# ---------------------------------------------------------------------------
# ask-time overhead: numpy path vs fused device engine
# ---------------------------------------------------------------------------
def _synthetic_history(space, n_obs: int, seed: int):
    """Deterministic (config, raw-result) pairs covering every index type —
    a cheap stand-in workload so the benchmark measures recommendation, not
    evaluation."""
    rng = np.random.default_rng(seed + 1)
    cfgs = [space.default_config(t) for t in space.type_names]
    cfgs += space.sample(rng, max(n_obs - len(cfgs), 0))
    cfgs = cfgs[:n_obs]
    out = []
    for cfg in cfgs:
        x = space.encode(cfg)
        h = float(np.sin(7.0 * x).sum())
        speed = 1000.0 * (1.2 + np.tanh(h))
        recall = 0.6 + 0.39 * (0.5 + 0.5 * np.tanh(2.0 * x.mean() + 0.3 * h))
        out.append((cfg, {"speed": speed, "recall": recall, "mem_gib": 1.0 + x.mean()}))
    return out


def _preloaded_tuner(space, history, seed, q, engine, warm_start, n_candidates, mc_samples):
    tuner = VDTuner(
        space, seed=seed, q=q, engine=engine, warm_start=warm_start,
        n_candidates=n_candidates, mc_samples=mc_samples,
    )
    for cfg, raw in history:
        tuner.tell(cfg, raw)
    return tuner


def run_ask_overhead(
    n_obs: int = 128,
    qs: Sequence[int] = (1, 4, 8),
    n_ask: int = 5,
    seed: int = 0,
    n_candidates: int = 512,
    mc_samples: int = 64,
    warm: bool = True,
) -> Dict:
    """Time ``ask()`` on a preloaded history of ``n_obs`` observations.

    The numpy engine runs the pre-PR configuration (cold 120-step GP fits,
    host-side greedy acquisition); the jax engine runs the fused device path
    with warm-started refits. Each (engine, q) cell does one untimed
    compile/warm-up ask, then reports the mean of ``n_ask`` timed asks.
    """
    space = make_space()
    history = _synthetic_history(space, n_obs, seed)
    engines: Dict[str, Dict] = {}
    for engine in ("numpy", "jax"):
        engines[engine] = {}
        for q in qs:
            tuner = _preloaded_tuner(
                space, history, seed, q, engine,
                warm_start=(engine == "jax" and warm), n_candidates=n_candidates,
                mc_samples=mc_samples,
            )
            t0 = time.perf_counter()
            tuner.ask(q)  # jit compile (cold-fit program)
            cold_s = time.perf_counter() - t0
            tuner.ask(q)  # second warm-up: compiles the warm-fit program too
            times = []
            for _ in range(n_ask):
                t0 = time.perf_counter()
                tuner.ask(q)
                times.append(time.perf_counter() - t0)
            mean_s = float(np.mean(times))
            engines[engine][f"q{q}"] = {
                "ask_s_mean": mean_s,
                "ask_s_cold": float(cold_s),
                "recommend_s_per_iter": mean_s / q,
            }
            emit(
                f"ask_overhead/{engine}/q{q}", mean_s / q * 1e6,
                f"ask={mean_s*1e3:.1f}ms;cold={cold_s*1e3:.0f}ms;n={n_obs}",
            )
    speedups = {
        f"q{q}": (
            engines["numpy"][f"q{q}"]["recommend_s_per_iter"]
            / engines["jax"][f"q{q}"]["recommend_s_per_iter"]
        )
        for q in qs
    }
    return {
        "schema": 1,
        "n_obs": n_obs,
        "n_ask": n_ask,
        "n_candidates": n_candidates,
        "mc_samples": mc_samples,
        "seed": seed,
        "warm_start": warm,
        "engines": engines,
        "speedup_per_iter": speedups,
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--table-vi", action="store_true", help="run the paper Table VI breakdown")
    p.add_argument("--n-obs", type=int, default=128)
    p.add_argument("--qs", type=int, nargs="+", default=[1, 4, 8])
    p.add_argument("--n-ask", type=int, default=5)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--n-candidates", type=int, default=512)
    p.add_argument("--mc-samples", type=int, default=64)
    p.add_argument("--no-warm", action="store_true", help="disable warm-started GP refits")
    p.add_argument("--quick", action="store_true", help="CI-sized budget (n_obs=64, q in {1,4}, 3 asks)")
    p.add_argument("--json", dest="json_path", default=None, help="write results to this path")
    p.add_argument(
        "--check-speedup", action="store_true",
        help="exit non-zero unless the fused engine is >= 3x faster per iteration at q=4",
    )
    p.add_argument(
        "--check-against", default=None, metavar="BASELINE_JSON",
        help="exit non-zero if fused q=4 recommend_s_per_iter regresses more than "
        "2x against the checked-in baseline number",
    )
    args = p.parse_args(argv)

    if args.table_vi:
        print(run(seed=args.seed))
        return 0

    kw = dict(
        n_obs=args.n_obs, qs=tuple(args.qs), n_ask=args.n_ask, seed=args.seed,
        n_candidates=args.n_candidates, mc_samples=args.mc_samples, warm=not args.no_warm,
    )
    if args.quick:
        kw.update(n_obs=64, qs=(1, 4), n_ask=3, n_candidates=256)
    out = run_ask_overhead(**kw)
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_path}")

    rc = 0
    if args.check_speedup:
        s = out["speedup_per_iter"].get("q4")
        if s is None or s < 3.0:
            print(f"FAIL: fused-engine speedup at q=4 is {s} (< 3x)")
            rc = 1
        else:
            print(f"OK: fused-engine speedup at q=4 is {s:.2f}x (>= 3x)")
    if args.check_against:
        with open(args.check_against) as f:
            baseline = json.load(f)
        limit = 2.0 * baseline["recommend_s_per_iter_q4"]
        cell = out["engines"]["jax"].get("q4")
        if cell is None:
            print("FAIL: --check-against needs q=4 in --qs")
            return 1
        got = cell["recommend_s_per_iter"]
        if got > limit:
            print(
                f"FAIL: fused q=4 recommend_s_per_iter {got*1e3:.1f}ms exceeds 2x "
                f"baseline ({baseline['recommend_s_per_iter_q4']*1e3:.1f}ms)"
            )
            rc = 1
        else:
            print(f"OK: fused q=4 recommend_s_per_iter {got*1e3:.1f}ms within 2x baseline")
    return rc


if __name__ == "__main__":
    sys.exit(main())
