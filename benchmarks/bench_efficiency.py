"""Paper Fig. 6 + Fig. 7: best search speed at recall floors per method, and
samples/time needed to reach the most-competitive-baseline quality."""
from __future__ import annotations

import numpy as np

from repro.vdms import make_space

from .common import DATASETS, N_ITERS, RECALL_FLOORS, emit, make_env, run_method

METHODS = ("vdtuner", "random_lhs", "ottertune", "qehvi", "opentuner")


def speed_at_floors(tuner):
    return {r: tuner.best_speed_at_recall(r) for r in RECALL_FLOORS}


def iters_to_reach(tuner, floor: float, target_speed: float):
    best = -np.inf
    for o in tuner.history:
        if not o.failed and o.y[1] >= floor:
            best = max(best, o.y[0])
        if best >= target_speed:
            return o.iteration + 1
    return None


def run(seed: int = 0, datasets=DATASETS):
    space = make_space()
    out = {}
    for ds in datasets:
        env = make_env(ds, seed=seed)
        results, walls = {}, {}
        for m in METHODS:
            tuner, wall = run_method(m, env, space, N_ITERS, seed=seed)
            results[m] = tuner
            walls[m] = wall
        table = {m: speed_at_floors(t) for m, t in results.items()}
        # tuning efficiency (Fig. 7): iterations for vdtuner to match the most
        # competitive baseline at each floor
        eff = {}
        for r in RECALL_FLOORS:
            base_best = max(
                (table[m][r] for m in METHODS if m != "vdtuner" and np.isfinite(table[m][r])),
                default=float("nan"),
            )
            eff[r] = iters_to_reach(results["vdtuner"], r, base_best)
        # trade-off ability (std of speeds across floors; lower = better)
        tradeoff = {
            m: float(np.nanstd([table[m][r] for r in RECALL_FLOORS])) for m in METHODS
        }
        out[ds] = {"speed_at_floor": table, "iters_to_match_best_baseline": eff,
                   "tradeoff_std": tradeoff, "wall_s": walls}
        for m in METHODS:
            vals = ";".join(
                f"r{r}={table[m][r]:.0f}" if np.isfinite(table[m][r]) else f"r{r}=nan"
                for r in (0.85, 0.95, 0.99)
            )
            emit(f"efficiency/{ds}/{m}", walls[m] * 1e6 / N_ITERS, vals)
    return out


if __name__ == "__main__":
    print(run())
