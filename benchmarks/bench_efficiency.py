"""Paper Fig. 6 + Fig. 7: best search speed at recall floors per method, and
samples/time needed to reach the most-competitive-baseline quality.

Also exposes the batch-parallel tuning axis: ``--batch-sizes 1 4`` runs the
same VDTuner iteration budget at each ``q`` and reports wall-clock tuning
time vs. batch size (``--check-speedup`` turns a q>1 regression into a
non-zero exit for CI smoke-bench gating).

Every tuner is driven through ``TuningSession`` — one harness for all
methods — and the ``--json`` output carries a ``session`` block per run: the
per-iteration recommend/eval time ledger with a stable schema
(``repro.core.session.LEDGER_SCHEMA``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import TuningSession, VDTuner, hv_2d, pareto_front
from repro.vdms import make_space

from .common import DATASETS, N_ITERS, RECALL_FLOORS, emit, make_env, run_method

METHODS = ("vdtuner", "random_lhs", "ottertune", "qehvi", "opentuner")


def speed_at_floors(tuner):
    return {r: tuner.best_speed_at_recall(r) for r in RECALL_FLOORS}


def iters_to_reach(tuner, floor: float, target_speed: float):
    best = -np.inf
    for o in tuner.history:
        if not o.failed and o.y[1] >= floor:
            best = max(best, o.y[0])
        if best >= target_speed:
            return o.iteration + 1
    return None


def run(seed: int = 0, datasets=DATASETS):
    space = make_space()
    out = {}
    for ds in datasets:
        env = make_env(ds, seed=seed)
        results, walls, ledgers = {}, {}, {}
        for m in METHODS:
            tuner, wall, session = run_method(m, env, space, N_ITERS, seed=seed)
            results[m] = tuner
            walls[m] = wall
            ledgers[m] = session.ledger_dict()
        table = {m: speed_at_floors(t) for m, t in results.items()}
        # tuning efficiency (Fig. 7): iterations for vdtuner to match the most
        # competitive baseline at each floor
        eff = {}
        for r in RECALL_FLOORS:
            base_best = max(
                (table[m][r] for m in METHODS if m != "vdtuner" and np.isfinite(table[m][r])),
                default=float("nan"),
            )
            eff[r] = iters_to_reach(results["vdtuner"], r, base_best)
        # trade-off ability (std of speeds across floors; lower = better)
        tradeoff = {
            m: float(np.nanstd([table[m][r] for r in RECALL_FLOORS])) for m in METHODS
        }
        out[ds] = {"speed_at_floor": table, "iters_to_match_best_baseline": eff,
                   "tradeoff_std": tradeoff, "wall_s": walls,
                   "session": ledgers}
        for m in METHODS:
            vals = ";".join(
                f"r{r}={table[m][r]:.0f}" if np.isfinite(table[m][r]) else f"r{r}=nan"
                for r in (0.85, 0.95, 0.99)
            )
            emit(f"efficiency/{ds}/{m}", walls[m] * 1e6 / N_ITERS, vals)
    return out


def run_batched(
    seed: int = 0,
    dataset: str = "glove_like",
    batch_sizes=(1, 4),
    n_iters: int = N_ITERS,
    mode: str = "analytic",
):
    """Wall-clock tuning time vs. batch size q at a fixed iteration budget.

    Each q gets a fresh environment (cold caches, cold compile) so the
    comparison reflects a full tuning session. Reports total wall, the
    recommendation/evaluation split, and the normalized Pareto hypervolume so
    speedups can't silently trade away tuning quality.
    """
    space = make_space()
    out = {}
    for q in batch_sizes:
        env = make_env(dataset, seed=seed, mode=mode)
        tuner = VDTuner(space, env, seed=seed, q=int(q))
        session = TuningSession(tuner)
        t0 = time.perf_counter()
        session.run(n_iters)
        wall = time.perf_counter() - t0
        ys = tuner.Y
        norm = ys.max(axis=0)
        norm = np.where(norm <= 0, 1.0, norm)
        hv = hv_2d(pareto_front(ys) / norm, np.zeros(2))
        out[str(q)] = {
            "q": int(q),
            "n_iters": n_iters,
            "wall_s": wall,
            "recommend_s": float(sum(o.recommend_time for o in tuner.history)),
            "replay_s": float(env.total_replay_time),
            "n_evals": int(env.n_evals),
            "hv_norm": float(hv),
            "session": session.ledger_dict(),
        }
        emit(f"efficiency_batched/{dataset}/q{q}", wall * 1e6 / n_iters,
             f"wall={wall:.2f}s;hv={hv:.3f}")
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--batch-sizes", type=int, nargs="+", default=None,
                   help="run the batched-tuning axis at these q values "
                        "(omit to run the full Fig. 6/7 method comparison)")
    p.add_argument("--iters", type=int, default=None,
                   help=f"iteration budget for the batched axis (default {N_ITERS})")
    p.add_argument("--dataset", default=None,
                   help="dataset for the batched axis (default glove_like)")
    p.add_argument("--mode", default=None, choices=("analytic", "wall"),
                   help="measurement mode for the batched axis (default analytic)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write results as JSON (CI artifact)")
    p.add_argument("--check-speedup", action="store_true",
                   help="exit 1 unless every q>1 wall-clock is strictly below q=1")
    args = p.parse_args(argv)
    args.iters = args.iters if args.iters is not None else N_ITERS
    args.dataset = args.dataset or "glove_like"
    args.mode = args.mode or "analytic"

    if args.batch_sizes is None:
        if (args.iters, args.dataset, args.mode) != (N_ITERS, "glove_like", "analytic"):
            p.error("--iters/--dataset/--mode only apply with --batch-sizes; the "
                    "full figure run is configured via REPRO_BENCH_FULL")
        results = run(seed=args.seed)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(results, f, indent=2, default=str)
        print(results)
        return 0

    results = run_batched(seed=args.seed, dataset=args.dataset,
                          batch_sizes=args.batch_sizes, n_iters=args.iters,
                          mode=args.mode)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    for q, r in results.items():
        print(f"q={q}: wall={r['wall_s']:.2f}s recommend={r['recommend_s']:.2f}s "
              f"replay={r['replay_s']:.2f}s hv={r['hv_norm']:.3f}")
    if args.check_speedup and "1" in results:
        base = results["1"]["wall_s"]
        slow = {q: r["wall_s"] for q, r in results.items()
                if r["q"] > 1 and r["wall_s"] >= base}
        if slow:
            print(f"SPEEDUP REGRESSION: q=1 wall {base:.2f}s, slower batched runs: "
                  f"{ {q: round(w, 2) for q, w in slow.items()} }", file=sys.stderr)
            return 1
        print(f"speedup check OK: q=1 {base:.2f}s > " +
              ", ".join(f"q={r['q']} {r['wall_s']:.2f}s"
                        for r in results.values() if r["q"] > 1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
