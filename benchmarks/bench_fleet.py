"""Fleet tuning: warm-started arrivals vs cold start under a shared budget.

A four-tenant fleet spanning two workload families (``glove_like`` and
``keyword_like``, two seeds each) runs through ``repro.fleet``:

1. **Establish** — the first tenant of each family tunes cold under the
   shared-budget scheduler, producing the ledgers transfer draws from.
2. **Arrive warm** — a new tenant per family joins, is warm-started from the
   most similar established tenants (``FleetSession.warm_start``: descriptor
   embedding -> ranked sources -> noise-inflated observation import), and
   tunes under the ``gain_per_cost`` scheduler.
3. **Cold baselines** — the same arrivals (identical seeds, fresh envs) tune
   solo with no transfer: the control arm.

Scoring is *eval-seconds to target hypervolume*: the cumulative analytic
evaluation cost a tenant is charged before its fresh-observation front first
reaches 90% of the cold arm's final hypervolume. Warm tenants skip the
mandatory per-index-type default sweep (their imports mark every type seen)
and start from an informed surrogate, so they should cross the target
strictly cheaper.

``--check-improvement`` exits non-zero unless, per family:

* the warm arrival reaches the target in strictly fewer eval-seconds than
  the cold baseline,
* the no-similar-tenant fallback (similarity floor at 1.0) tracks the cold
  baseline's trajectory exactly (never worse than cold start), and
* a mid-run ``state_dict`` -> restore round-trip reproduces the remaining
  rounds bit-identically (configs, objectives, charges, scheduler state).

``BENCH_fleet.json`` records per-tenant rounds, transfer reports, the
crossing points and the fleet ledger (CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import TuningSession, VDTuner
from repro.fleet import (
    FleetBudget,
    FleetScheduler,
    FleetSession,
    TransferPolicy,
    describe_env,
)
from repro.vdms import VDMSTuningEnv, make_space, make_trace

from .common import emit

#: (family, seed) per tenant — one established + one arrival per family
ESTABLISHED = (("glove_like", 0), ("keyword_like", 1))
ARRIVALS = (("glove_like", 7), ("keyword_like", 8))
MIX = (0.20, 0.75, 0.05)
TARGET_FRAC = 0.9  # target HV = this fraction of the cold arm's final HV


def _sizes(quick: bool):
    if quick:
        return dict(n_base=512, n_ops=160, n_iters=12)
    return dict(n_base=1024, n_ops=384, n_iters=16)


def _tenant_name(family: str, seed: int) -> str:
    return f"{family}-{seed}"


def _make_tenant(family: str, seed: int, sz) -> tuple:
    """Fresh (session, descriptor) for one tenant — identical construction
    for warm, fallback and cold arms, so trajectories are comparable."""
    trace = make_trace(
        family, n_base=sz["n_base"], n_ops=sz["n_ops"], seed=seed, mix=MIX,
    )
    env = VDMSTuningEnv(
        trace=trace, workload="streaming", mode="analytic", seed=seed, n_phases=1,
    )
    tuner = VDTuner(make_space(), env, seed=seed, warm_start=True)
    return TuningSession(tuner), describe_env(env, name=_tenant_name(family, seed))


def _round_trajectory(tenant) -> list:
    """The deterministic per-round projection two arms are compared on
    (budget_spent_s is fleet-wide, so it is excluded)."""
    return [
        (r["n_evals"], r["cost_s"], r["hv"], r["hv_gain"]) for r in tenant.rounds
    ]


def _history_projection(session) -> list:
    return [
        (o.config, [float(v) for v in o.y], o.failed, o.bootstrap, o.noise_scale)
        for o in session.tuner.history
    ]


def _seconds_to_target(tenant, target: float):
    """Cumulative charged eval-seconds at the first round whose fresh-front
    hypervolume reaches ``target`` — None when it never does."""
    cum = 0.0
    for r in tenant.rounds:
        cum += r["cost_s"]
        if r["hv"] >= target:
            return cum
    return None


def _run_cold(family: str, seed: int, sz) -> object:
    """Solo cold-start arm: same tenant construction, no transfer."""
    fleet = FleetSession(FleetBudget(1e9))
    session, desc = _make_tenant(family, seed, sz)
    fleet.add_tenant(_tenant_name(family, seed), session, desc, n_iters=sz["n_iters"])
    fleet.run()
    return fleet.tenant(_tenant_name(family, seed))


def _build_fleet(sz, policy: TransferPolicy) -> FleetSession:
    return FleetSession(
        FleetBudget(1e9),
        scheduler=FleetScheduler("gain_per_cost"),
        transfer_policy=policy,
    )


def _establish(fleet: FleetSession, sz) -> None:
    for family, seed in ESTABLISHED:
        session, desc = _make_tenant(family, seed, sz)
        fleet.add_tenant(_tenant_name(family, seed), session, desc, n_iters=sz["n_iters"])
    fleet.run()


def _add_arrivals(fleet: FleetSession, sz) -> list:
    reports = []
    for family, seed in ARRIVALS:
        session, desc = _make_tenant(family, seed, sz)
        fleet.add_tenant(_tenant_name(family, seed), session, desc, n_iters=sz["n_iters"])
        reports.append(fleet.warm_start(_tenant_name(family, seed)))
    return reports


def _resume_check(fleet_state: dict, sz, policy: TransferPolicy, want: dict) -> bool:
    """Restore a fresh fleet from ``fleet_state`` (JSON round-tripped), run it
    to completion, and compare the deterministic projection against the
    uninterrupted run's."""
    resumed = _build_fleet(sz, policy)
    for family, seed in ESTABLISHED + ARRIVALS:
        session, desc = _make_tenant(family, seed, sz)
        resumed.add_tenant(
            _tenant_name(family, seed), session, desc, n_iters=sz["n_iters"]
        )
    resumed.load_state_dict(json.loads(json.dumps(fleet_state)))
    resumed.run()
    got = {
        "scheduler": resumed.scheduler.state_dict(),
        "spent_s": resumed.budget.spent_s,
        "tenants": {
            n: {
                "rounds": _round_trajectory(resumed.tenant(n)),
                "history": _history_projection(resumed.session_of(n)),
            }
            for n in resumed.tenant_names
        },
    }
    return got == want


def run(seed: int = 0, quick: bool = True):
    sz = _sizes(quick)
    policy = TransferPolicy()
    out = {"sizes": dict(sz), "families": {}}

    # cold baselines for the arrivals (the control arm)
    cold = {}
    for family, aseed in ARRIVALS:
        cold[family] = _run_cold(family, aseed, sz)

    # establish the fleet, then warm-start the arrivals off it
    fleet = _build_fleet(sz, policy)
    _establish(fleet, sz)
    reports = _add_arrivals(fleet, sz)

    # a few scheduled rounds into the arrivals' tuning, checkpoint the whole
    # fleet mid-run, then finish; the resume arm must reproduce the rest
    for _ in range(3):
        runnable = [n for n in fleet.tenant_names if fleet.tenant(n).wants_more]
        if not runnable:
            break
        fleet.run_tenant_round(fleet.scheduler.pick(fleet.tenant_names, runnable))
    mid_state = fleet.state_dict()
    fleet.run()
    want = {
        "scheduler": fleet.scheduler.state_dict(),
        "spent_s": fleet.budget.spent_s,
        "tenants": {
            n: {
                "rounds": _round_trajectory(fleet.tenant(n)),
                "history": _history_projection(fleet.session_of(n)),
            }
            for n in fleet.tenant_names
        },
    }
    resume_ok = _resume_check(mid_state, sz, policy, want)

    # fallback arm: a similarity floor no real tenant clears -> cold start
    fallback_policy = TransferPolicy(min_similarity=1.0)
    fb_fleet = _build_fleet(sz, fallback_policy)
    _establish(fb_fleet, sz)
    fb_reports = _add_arrivals(fb_fleet, sz)
    fb_fleet.run()

    for (family, aseed), report, fb_report in zip(ARRIVALS, reports, fb_reports):
        name = _tenant_name(family, aseed)
        warm_t = fleet.tenant(name)
        cold_t = cold[family]
        fb_t = fb_fleet.tenant(name)
        target = TARGET_FRAC * cold_t.last_hv
        warm_s = _seconds_to_target(warm_t, target)
        cold_s = _seconds_to_target(cold_t, target)
        fallback_matches_cold = (
            fb_report.fallback
            and _round_trajectory(fb_t) == _round_trajectory(cold_t)
            and _history_projection(fb_t.session) == _history_projection(cold_t.session)
        )
        out["families"][family] = {
            "tenant": name,
            "target_hv": target,
            "cold_final_hv": cold_t.last_hv,
            "warm_final_hv": warm_t.last_hv,
            "cold_seconds_to_target": cold_s,
            "warm_seconds_to_target": warm_s,
            "warm_wins": warm_s is not None
            and cold_s is not None
            and warm_s < cold_s,
            "transfer": report.to_dict(),
            "fallback_transfer": fb_report.to_dict(),
            "fallback_matches_cold": fallback_matches_cold,
            "cold_rounds": [dict(r) for r in cold_t.rounds],
            "warm_rounds": [dict(r) for r in warm_t.rounds],
        }
        emit(
            f"fleet/{family}/warm_vs_cold",
            (warm_s or 0.0) * 1e6,
            f"cold_s={cold_s};warm_s={warm_s};"
            f"imported={report.n_imported};fallback_ok={fallback_matches_cold}",
        )

    out["resume_bit_identical"] = resume_ok
    out["ledger"] = fleet.ledger_dict()
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true", help="CI-sized budgets")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default=None, metavar="PATH", help="write results as JSON (CI artifact)")
    p.add_argument(
        "--check-improvement", action="store_true",
        help="exit 1 unless warm arrivals beat cold start per family, the "
             "no-source fallback tracks cold exactly, and mid-run resume is "
             "bit-identical",
    )
    args = p.parse_args(argv)

    out = run(seed=args.seed, quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)

    ok = bool(out["resume_bit_identical"])
    for family, r in out["families"].items():
        print(
            f"{family}: cold {r['cold_seconds_to_target']}s -> "
            f"warm {r['warm_seconds_to_target']}s to {TARGET_FRAC:.0%} of cold "
            f"final HV ({r['cold_final_hv']:.1f}); "
            f"imported={r['transfer']['n_imported']}, "
            f"fallback_matches_cold={r['fallback_matches_cold']}"
        )
        ok = ok and r["warm_wins"] and r["fallback_matches_cold"]
    print(f"resume_bit_identical={out['resume_bit_identical']}")

    if args.check_improvement and not ok:
        print(
            "FLEET CHECK FAILED: warm arrivals must reach target HV strictly "
            "cheaper than cold, the fallback must track cold exactly, and "
            "mid-run resume must be bit-identical",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
