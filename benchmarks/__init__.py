"""Benchmark suite (run modules via ``python -m benchmarks.<name>``)."""
