"""Streaming tuning demo: track a drifting workload with one TuningSession.

Generates a drifting trace (search-heavy -> insert-heavy, vectors blending
toward a different distribution), tunes on the first phase, then probes the
deployed incumbent as the workload moves; when the DriftDetector fires, the
session re-enters BO (stale measurements dropped, GP hyperparameters warm,
deployed front re-anchored) and reports the refreshed incumbent.

Run: PYTHONPATH=src python examples/tune_streaming.py
"""
from __future__ import annotations

from repro.core import DriftDetector, TuningSession, VDTuner, streaming_sustained
from repro.vdms import VDMSTuningEnv, make_space, make_trace


def brief(cfg):
    keys = ("index_type", "nprobe", "nlist", "segment_max_size", "graceful_time")
    return {k: (round(v, 3) if isinstance(v, float) else v) for k, v in cfg.items() if k in keys}


def main() -> int:
    trace = make_trace(
        "glove_like",
        n_base=2048,
        n_ops=900,
        seed=0,
        drift="step",
        mix=(0.05, 0.90, 0.05),
        mix_to=(0.60, 0.30, 0.10),
    )
    env = VDMSTuningEnv(trace=trace, workload="streaming", mode="analytic", seed=0, n_phases=3)
    spec = streaming_sustained()
    tuner = VDTuner(make_space(), env, seed=0, warm_start=True, objective_spec=spec)
    session = TuningSession(tuner)
    session.run(9)
    incumbent = tuner.best_config()
    print(f"phase 0 incumbent: {brief(incumbent)}")

    detector = DriftDetector(metrics=("speed", "recall"), rel_threshold=0.12)
    session.probe_drift(detector, incumbent)  # phase-0 reference
    for phase in range(1, env.n_phases):
        env.set_phase(phase)
        fired = session.probe_drift(detector, incumbent)
        rel = detector.log[-1]["rel"]
        print(f"phase {phase}: probe drift rel={rel:.2f} fired={fired}")
        if fired:
            session.retune(8, reanchor=tuner.pareto_configs(max_n=3))
            incumbent = tuner.best_config()
            detector.reset()
            session.probe_drift(detector, incumbent)
            print(f"  re-tuned incumbent: {brief(incumbent)}")
    raw = env(incumbent)
    print(
        f"final phase: sustained_qps={spec(raw)[0]:.0f} recall={raw['recall']:.3f} "
        f"(seals={raw['n_seals']:.0f}, evals={env.n_evals})"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
