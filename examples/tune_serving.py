import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Beyond-paper: VDTuner tunes this framework's own serving/training stack.

Remat strategy plays the role of the index type; flash block sizes and
sequence-parallelism are the parameters; the conflicting objectives are
(estimated step throughput, HBM headroom), both extracted from real XLA
compiles of a reduced model on an 8-device host mesh.

    PYTHONPATH=src python examples/tune_serving.py
"""
import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, get_arch, reduce  # noqa: E402
from repro.core import VDTuner, pareto_front  # noqa: E402
from repro.tuning.serve_tuner import ServeTuningEnv, make_serving_space  # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        reduce(get_arch("glm4-9b")), name="tune-target", d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=512, vocab=1024, n_layers=4,
        param_dtype="bfloat16",
    )
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=512, global_batch=8)
    import repro.configs.base as base

    # register the shape so the env can reference it
    base.SHAPES["tune_shape"] = shape

    env = ServeTuningEnv(cfg, "tune_shape", mesh)
    space = make_serving_space()
    print("== tuning the serving stack (each eval = one XLA compile) ==")
    tuner = VDTuner(space, env, seed=0, abandon_window=4, n_candidates=64, mc_samples=32)
    tuner.run(10)
    print("   pareto (steps/s proxy, HBM headroom):")
    for s, h in pareto_front(tuner.Y):
        print(f"     {s:10.2f}   {h:.3f}")
    best = max((o for o in tuner.history if not o.failed), key=lambda o: o.y[0])
    print(f"   fastest: {best.config['index_type']} "
          f"bq={best.config['flash_bq']} bk={best.config['flash_bk']} "
          f"seq_parallel={best.config['seq_parallel']} "
          f"(mem {best.raw.get('mem_gib', float('nan')):.2f} GiB/dev)")


if __name__ == "__main__":
    main()
