"""Serving control plane demo: SLO guardrails + shadow/canary retune.

Replays a drifting trace (arrival mix swings insert-heavy while the vector
distribution steps to a new family) through ``ServingController``: the
incumbent config quietly falls through the recall floor mid-trace, the
sliding-window SLO monitor flags the breach, the session re-tunes on the
trailing trace window, the candidate is built as a *shadow* instance with
live traffic mirrored to both, and it is promoted only if it wins the
SLO-constrained score — otherwise serving state rolls back checkpoint-exact.

Exits non-zero unless the control loop actually engaged (at least one
breach-triggered retune resolved as a promote or a rollback), so CI can
gate on it. ``--ledger-json PATH`` dumps the metrics ledger as a CI
artifact.

Run: PYTHONPATH=src python examples/serve_controlled.py
"""
from __future__ import annotations

import argparse

from repro.core import TuningSession, VDTuner
from repro.serving import ControllerParams, ServingController, SLOSpec
from repro.vdms import VDMSTuningEnv, make_space, make_trace


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--ledger-json", default=None, metavar="PATH", help="dump the metrics ledger as JSON")
    args = p.parse_args(argv)

    trace = make_trace(
        "glove_like",
        n_base=800,
        n_ops=640,
        seed=0,
        drift="step",
        mix=(0.20, 0.75, 0.05),
        mix_to=(0.65, 0.30, 0.05),
    )
    # an incumbent that looks healthy pre-drift but leans on graceful_time
    # staleness — it loses the recall floor once inserts dominate
    incumbent = dict(make_space().default_config("FLAT"), segment_max_size=256, graceful_time=0.4)

    # tune on the pre-drift prefix, as the deployment that picked the
    # incumbent would have (the controller re-enters this session on breach)
    env = VDMSTuningEnv(
        trace=trace.window(0, 150), workload="streaming", mode="analytic", seed=0, n_phases=1
    )
    session = TuningSession(VDTuner(make_space(), env, seed=0, warm_start=True))
    session.run(6)

    slo = SLOSpec(recall_floor=0.9, min_samples=16)
    ctrl = ServingController(
        slo,
        session=session,
        params=ControllerParams(
            retune_iters=6,
            check_every=24,
            canary_queries=24,
            retune_window_ops=112,
            cooldown_ops=48,
            floor_margin=0.02,
        ),
        seed=0,
    )
    report = ctrl.serve(trace, incumbent, guard=True)

    for e in report["timeline"]:
        extra = {k: v for k, v in e.items() if k not in ("event", "op", "time")}
        print(f"op {e['op']:>4} t={e['time']:.2f} {e['event']:<16} {extra if extra else ''}")
    print(
        f"served {report['n_searches']} searches: recall={report['recall']:.3f} "
        f"p50={report['lat_p50_s'] * 1e3:.3f}ms p99={report['lat_p99_s'] * 1e3:.3f}ms"
    )
    print(
        f"SLO: {report['n_breach_events']} breach events, "
        f"{report['violation_minutes']:.2f} violation-minutes "
        f"({report['recall_under_floor_minutes']:.2f} under the recall floor)"
    )
    print(
        f"control loop: retunes={report['n_retunes']} promotes={report['n_promotes']} "
        f"rollbacks={report['n_rollbacks']} configs_served={len(report['config_history'])}"
    )
    if args.ledger_json:
        ctrl.ledger.dump_json(args.ledger_json)
        print(f"ledger -> {args.ledger_json}")

    # smoke gate: the breach must have engaged the loop end-to-end
    ok = report["n_breach_events"] >= 1 and report["n_retunes"] >= 1
    ok = ok and (report["n_promotes"] + report["n_rollbacks"]) >= 1
    if not ok:
        print("SMOKE FAILED: control loop never engaged")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
