"""User-preference tuning: maximize QPS subject to recall >= 0.9, and
bootstrap a second tuning session (tighter recall floor) from the first
session's data (paper §IV-F).

The recall floor is expressed as a first-class objective
(`repro.core.objectives.recall_floor`): the spec carries the constraint and
VDTuner switches to constrained EI automatically.

    PYTHONPATH=src python examples/tune_constrained.py
"""
from repro.core import TuningSession, VDTuner, recall_floor
from repro.vdms import VDMSTuningEnv, make_dataset, make_space


def main():
    ds = make_dataset("keyword_like", n=6144, n_queries=128, k=10, seed=1)
    env = VDMSTuningEnv(ds, mode="analytic", seed=1)
    space = make_space()

    print("== phase 1: recall >= 0.85 (constraint EI) ==")
    t1 = VDTuner(space, seed=1, objective_spec=recall_floor(0.85))
    TuningSession(t1, backend=env).run(25)
    print(f"   best qps @ recall>=0.85: {t1.best_speed_at_recall(0.85):.0f}")

    print("== phase 2: recall >= 0.92, bootstrapped from phase 1 ==")
    t2 = VDTuner(
        space, seed=2, objective_spec=recall_floor(0.92), bootstrap_history=t1.history
    )
    TuningSession(t2, backend=env).run(20)
    print(f"   best qps @ recall>=0.92: {t2.best_speed_at_recall(0.92):.0f}")

    feas = sum(1 for o in t2.history if not o.bootstrap and o.y[1] >= 0.92)
    total = sum(1 for o in t2.history if not o.bootstrap)
    print(f"   {feas}/{total} fresh samples were feasible — the constraint "
          f"model concentrates search inside the feasible region")


if __name__ == "__main__":
    main()
