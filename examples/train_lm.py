"""End-to-end driver: train a ~130M-parameter Mamba2 LM for a few hundred
steps on the synthetic token pipeline, with checkpointing + auto-resume +
straggler monitoring. Kill it mid-run and start it again: it resumes.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.launch.train import TrainConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-width", action="store_true",
                    help="true mamba2-130m width (slow on CPU); default is the reduced smoke config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    tcfg = TrainConfig(
        arch="mamba2-130m",
        smoke=not args.full_width,
        steps=args.steps,
        seq_len=256 if args.full_width else 128,
        global_batch=8,
        microbatch=4,
        lr=3e-4,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        log_every=20,
    )
    out = run(tcfg)
    print(f"trained: first loss {out['losses'][0]:.3f} -> final {out['final_loss']:.3f} "
          f"({len(out['losses'])} steps, median {1e3*(out['median_step_s'] or 0):.0f} ms/step)")


if __name__ == "__main__":
    main()
