"""Degraded-mode serving demo: breach -> fault -> degrade -> rebuild -> recover.

Replays the serving demo's drifting trace with a ``FaultPlan`` armed: a
build-crash budget arms just before two sealed segments die mid-trace, so
the first repair attempts crash and retry with backoff before succeeding.
The controller keeps serving throughout — quarantined segments drop out of
the visible set (coverage < 1), searches answer from the survivors plus the
growing tail, recall accounting is scored against the brute-force oracle
restricted to what was actually searchable, and background rebuilds restore
the lost segments from the authoritative vector store.

Exits non-zero unless degraded mode actually engaged (a quarantine
happened, a rebuild completed, and coverage dipped below 1) and the engine
finished healthy — so CI can gate on the whole loop, not just on "it ran".

Run: PYTHONPATH=src python examples/serve_chaos.py
"""
from __future__ import annotations

import sys

from repro.serving import ControllerParams, ServingController, SLOSpec
from repro.vdms import FaultEvent, FaultPlan, make_space, make_trace


def main() -> int:
    trace = make_trace(
        "glove_like", n_base=800, n_ops=640, seed=0, drift="step",
        mix=(0.20, 0.75, 0.05), mix_to=(0.65, 0.30, 0.05),
    )
    incumbent = dict(
        make_space().default_config("FLAT"), segment_max_size=256, graceful_time=0.4
    )
    # the engine fault clock ticks ~once per mutation/flush (~n_ops/2 here)
    plan = FaultPlan(
        events=(
            FaultEvent(kind="build_crash", at_tick=60, fails=2),
            FaultEvent(kind="segment_loss", at_tick=90, segment=0),
            FaultEvent(kind="segment_loss", at_tick=180, segment=1),
        ),
        seed=0,
    )

    slo = SLOSpec(recall_floor=0.9, min_samples=16)
    ctrl = ServingController(
        slo, params=ControllerParams(check_every=24), seed=0
    )
    report = ctrl.serve(trace, incumbent, guard=False, fault_plan=plan)

    for e in report["timeline"]:
        if e["event"] in ("health", "breach"):
            extra = {k: v for k, v in e.items() if k not in ("event", "op", "time")}
            print(f"op {e['op']:>4} t={e['time']:.2f} {e['event']:<8} {extra}")
    f = report["fault"]
    print(
        f"served {report['n_searches']} searches through "
        f"{f['n_injected']} injected faults: recall={report['recall']:.3f} "
        f"visible-set recall={report['visible_recall']:.3f}"
    )
    print(
        f"degraded mode: coverage dipped to {f['coverage_min']:.3f}, "
        f"{f['n_quarantines']} quarantines, {f['n_rebuilds']} rebuilds, "
        f"{f['n_seal_retries']} seal retries; final health={report['health']}"
    )

    engaged = (
        f["n_quarantines"] >= 1
        and f["n_rebuilds"] >= 1
        and f["coverage_min"] < 1.0
        and report["health"] == "healthy"
        and report["visible_recall"] == 1.0  # FLAT is exact on the visible set
    )
    if not engaged:
        print("FAILED: degraded mode never engaged (or did not recover)", file=sys.stderr)
        return 1
    print("ok: degraded, rebuilt, recovered — without lying about recall")
    return 0


if __name__ == "__main__":
    sys.exit(main())
