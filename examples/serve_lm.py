"""Batched serving example: prefill a batch of prompts on a GQA transformer
and decode tokens against the KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch glm4-9b --gen 48
"""
import argparse

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    out = run(args.arch, smoke=True, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen)
    print(f"prefill: {out['prefill_s']*1e3:.0f} ms for batch={args.batch} x {args.prompt_len} tokens")
    print(f"decode : {out['decode_tokens_per_s']:.1f} tokens/s over {args.gen} steps")
    print(f"sample token ids: {out['tokens'][0][:12].tolist()}")


if __name__ == "__main__":
    main()
