"""Quickstart: auto-tune a vector data management system with VDTuner.

Builds a small JAX-native VDMS over a synthetic angular-embedding dataset,
then drives VDTuner's polling multi-objective Bayesian optimization through
the ask/tell `TuningSession` API to find configurations that maximize BOTH
search speed (QPS) and recall@10 — and shows that a killed session resumes
bit-identically from a JSON checkpoint.

    PYTHONPATH=src python examples/quickstart.py

Exits non-zero if the checkpoint/resume round-trip diverges (CI runs this
file as the public-API smoke test).
"""
import json
import sys

from repro.core import StopSession, TuningSession, VDTuner, pareto_front, speed_recall
from repro.vdms import VDMSTuningEnv, make_dataset, make_space

N_ITERS = 30


def make_tuner(space):
    # the tuner is a pure recommender: ask(n) -> configs, tell(cfg, result).
    # objective_spec picks WHAT to maximize (see repro.core.objectives —
    # speed_recall, recall_floor(0.9), cost_aware(eta));
    # the session owns evaluation dispatch, budget, ledger, checkpoints.
    return VDTuner(space, seed=0, abandon_window=8, objective_spec=speed_recall())


def main() -> int:
    print("== building dataset + environment ==")
    ds = make_dataset("glove_like", n=6144, n_queries=128, k=10, seed=0)
    env = VDMSTuningEnv(ds, mode="analytic", seed=0)  # mode="wall" for real QPS
    space = make_space()

    print("== default (no tuning) ==")
    default = env(space.default_config("AUTOINDEX"))
    print(f"   AUTOINDEX default: qps={default['speed']:.0f} recall={default['recall']:.3f}")

    print(f"== VDTuner: {N_ITERS} iterations of polling MOBO via TuningSession ==")
    tuner = make_tuner(space)
    session = TuningSession(tuner, backend=env)
    session.run(N_ITERS)
    # (deprecated one-liner, same trajectory: VDTuner(space, env, seed=0,
    #  abandon_window=8).run(30) — kept as a thin shim over TuningSession.)

    ledger = session.ledger_dict()["totals"]
    print(f"   abandoned index types: {tuner.abandon.abandoned}")
    print(f"   time ledger: recommend={ledger['recommend_s']:.2f}s "
          f"eval={ledger['eval_s']:.2f}s over {ledger['n_rounds']} rounds")
    print("   Pareto front (speed, recall):")
    for spd, rec in pareto_front(tuner.Y):
        print(f"     qps={spd:9.0f}  recall={rec:.3f}")

    best = max(
        (o for o in tuner.history if not o.failed and o.y[1] >= default["recall"]),
        key=lambda o: o.y[0],
        default=None,
    )
    if best is not None:
        gain = (best.y[0] / default["speed"] - 1) * 100
        print(f"   best at >= default recall: {best.index_type} "
              f"(+{gain:.0f}% qps, recall {best.y[1]:.3f})")
        print(f"   config: { {k: v for k, v in best.config.items() if k != 'index_type'} }")

    # -- checkpoint/resume: kill the session mid-run, restore, continue -----
    print("== checkpoint/resume: interrupt at 12 observations, restore, rerun ==")

    def interrupt(sess, obs):
        if sess.n_observations >= 12:
            raise StopSession

    part = TuningSession(make_tuner(space), backend=env, callbacks=[interrupt])
    part.run(N_ITERS)
    checkpoint = json.dumps(part.state_dict())  # JSON all the way to disk
    print(f"   checkpoint after {part.n_observations} observations "
          f"({len(checkpoint)} bytes of JSON)")

    resumed = TuningSession.restore(json.loads(checkpoint), make_tuner(space), backend=env)
    resumed.run(N_ITERS)

    want = [(o.config, tuple(o.y), o.failed) for o in tuner.history]
    got = [(o.config, tuple(o.y), o.failed) for o in resumed.tuner.history]
    if got != want:
        print("   RESUME MISMATCH: restored session diverged from the "
              "uninterrupted run", file=sys.stderr)
        return 1
    print(f"   resumed run is bit-identical to the uninterrupted one "
          f"({len(got)} observations)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
