"""Quickstart: auto-tune a vector data management system with VDTuner.

Builds a small JAX-native VDMS over a synthetic angular-embedding dataset,
then runs VDTuner's polling multi-objective Bayesian optimization to find
configurations that maximize BOTH search speed (QPS) and recall@10.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import VDTuner, pareto_front
from repro.vdms import VDMSTuningEnv, make_dataset, make_space


def main():
    print("== building dataset + environment ==")
    ds = make_dataset("glove_like", n=6144, n_queries=128, k=10, seed=0)
    env = VDMSTuningEnv(ds, mode="analytic", seed=0)  # mode="wall" for real QPS
    space = make_space()

    print("== default (no tuning) ==")
    default = env(space.default_config("AUTOINDEX"))
    print(f"   AUTOINDEX default: qps={default['speed']:.0f} recall={default['recall']:.3f}")

    print("== VDTuner: 30 iterations of polling MOBO ==")
    tuner = VDTuner(space, env, seed=0, abandon_window=8)
    tuner.run(30)

    print(f"   abandoned index types: {tuner.abandon.abandoned}")
    print("   Pareto front (speed, recall):")
    for spd, rec in pareto_front(tuner.Y):
        print(f"     qps={spd:9.0f}  recall={rec:.3f}")

    best = max(
        (o for o in tuner.history if not o.failed and o.y[1] >= default["recall"]),
        key=lambda o: o.y[0],
        default=None,
    )
    if best is not None:
        gain = (best.y[0] / default["speed"] - 1) * 100
        print(f"   best at >= default recall: {best.index_type} "
              f"(+{gain:.0f}% qps, recall {best.y[1]:.3f})")
        print(f"   config: { {k: v for k, v in best.config.items() if k != 'index_type'} }")


if __name__ == "__main__":
    main()
