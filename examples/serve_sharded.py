"""Sharded multi-device serving: place a sealed corpus on a mesh and drive it.

Walks the full sharded serving path on a host-emulated 4-device mesh (the
``XLA_FLAGS`` line below must run before JAX imports):

1. bulk-build a corpus with :class:`~repro.vdms.engine.VDMSInstance`;
2. place its sealed segments across 1/2/4 shards
   (:class:`~repro.vdms.sharded.ShardedVDMS`) and verify the shard-count
   invariants — identical recall, identical (gid, score) sets, >= trend
   analytic QPS scaling;
3. attach the serving metrics ledger (``attach_sharded``) and offer
   multi-stream Poisson load (:func:`~repro.vdms.replay_query_streams`);
4. snapshot a tombstoned :class:`~repro.vdms.engine.LiveVDMS` with
   ``from_live`` and confirm the 1-shard snapshot is bit-identical.

Run: PYTHONPATH=src python examples/serve_sharded.py
(CI runs this file in the api-smoke job; exits non-zero on failure.)
"""
from __future__ import annotations

import os
import sys

# emulate a 4-device mesh on one host BEFORE jax initializes
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import numpy as np  # noqa: E402

from repro.serving import attach_sharded, serving_ledger  # noqa: E402
from repro.vdms import (  # noqa: E402
    LiveVDMS,
    ShardedVDMS,
    VDMSInstance,
    make_dataset,
    recall_at_k,
    replay_query_streams,
)

CONFIG = dict(
    index_type="IVF_SQ8", nlist=32, nprobe=8, kmeans_iters=3,
    segment_max_size=2048, seal_proportion=1.0, search_batch_size=32,
    graceful_time=0.2, topk_merge_width=32, storage_bf16=False,
)


def main() -> int:
    import jax

    print(f"== mesh: {len(jax.devices())} devices ==")
    ds = make_dataset("glove_like", n=65536, n_queries=64, dim=64, k=10, seed=0)
    inst = VDMSInstance(ds, CONFIG, seed=0)
    print(f"   built {inst.plan.n_sealed} sealed segments over {ds.n} vectors")

    print("== shard-count invariants (1 -> 2 -> 4 shards) ==")
    results = {}
    for n in (1, 2, 4):
        sharded = ShardedVDMS.from_instance(inst, n_shards=n)
        ids, elapsed = sharded.search(ds.queries, 10, mode="analytic")
        recall = recall_at_k(ids, ds.ground_truth)
        results[n] = (ids, elapsed, recall, sharded)
        print(
            f"   {n} shards ({sharded.dispatch}): qps={ds.queries.shape[0] / elapsed:.0f} "
            f"recall={recall:.3f}"
        )
    ids1 = results[1][0]
    assert all(np.array_equal(results[n][0], ids1) for n in (2, 4)), \
        "shard count changed the returned ids"
    assert len({results[n][2] for n in (1, 2, 4)}) == 1, "recall diverged"
    assert results[4][1] < results[1][1], "4 shards must be faster than 1 (analytic)"
    print("   invariants hold: identical ids, identical recall, QPS scales")

    print("== Poisson multi-stream serving with the metrics ledger ==")
    sharded = results[4][3]
    ledger = serving_ledger()
    attach_sharded(ledger, sharded)
    qps = ds.queries.shape[0] / results[4][1]
    rep = replay_query_streams(
        sharded, ds.queries, rate=0.5 * qps, n_streams=8, n_per_stream=16, topk=10,
    )
    print(
        f"   offered={rep['offered_qps']:.0f}/s served={rep['served_qps']:.0f}/s "
        f"p99={rep['sojourn_p99_s'] * 1e3:.2f}ms util={rep['utilization']:.2f}"
    )
    assert ledger.get("vdms_queries_total").value > 0, "ledger saw no queries"
    assert ledger.get("vdms_shards").value == 4.0
    print(f"   ledger: shards={ledger.get('vdms_shards').value:.0f} "
          f"queries={ledger.get('vdms_queries_total').value:.0f} "
          f"skew={ledger.get('vdms_shard_skew').value:.2f}")

    print("== live snapshot: tombstones + growing tail, sharded ==")
    live = LiveVDMS(CONFIG, dim=64, capacity=65536, seed=0)
    live.insert(ds.data[:20000])
    rng = np.random.default_rng(0)
    for g in rng.choice(16000, 800, replace=False):
        live.delete(int(g))
    live_ids, _ = live.search(ds.queries, 10)
    snap = ShardedVDMS.from_live(live, n_shards=1)
    snap_ids, _ = snap.search(ds.queries, 10, mode="analytic")
    assert np.array_equal(snap_ids, live_ids), "1-shard live snapshot must be bit-identical"
    snap4 = ShardedVDMS.from_live(live, n_shards=4)
    ids4, _ = snap4.search(ds.queries, 10, mode="analytic")
    assert np.array_equal(ids4, live_ids), "4-shard live snapshot changed results"
    st = snap4.stats()
    print(
        f"   live snapshot serves identically at 4 shards "
        f"(min shard coverage {st['min_shard_coverage']:.3f}, "
        f"tail {st['growing_size']} rows)"
    )
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
