"""Extending the VDMS: register a custom index family via the public hook.

One ``register_family`` call is the ONLY integration step: the registry then
derives the search space (``make_space``), routes engine build/search/seal
dispatch, and the tuning session optimizes the new family's parameters next
to the built-ins — zero edits to ``core/space.py``, ``tuning_env.py``, or
the session layer.

The worked example is the DiskANN-style ``IVF_PQR`` family shipped in
``repro.vdms.ivf_pqr`` (PQ candidate scan + exact re-rank with a tunable
``reorder_k``); this script registers it, shows the derived space, tunes it
against two built-ins, and replays a small streaming trace through it.

Run: PYTHONPATH=src python examples/custom_index_family.py
(CI runs this file in the api-smoke job; exits non-zero on failure.)
"""
from __future__ import annotations

import sys

from repro.core import TuningSession, VDTuner, pareto_front
from repro.vdms import (
    VDMSTuningEnv,
    ivf_pqr,
    make_dataset,
    make_space,
    make_trace,
    registered_names,
    replay_trace,
)


def main() -> int:
    print("== registering IVF_PQR through the public hook ==")
    family = ivf_pqr.register()  # the ONE integration call
    print(f"   registered families: {', '.join(registered_names())}")
    print(
        f"   {family.name}: params={[p.name for p in family.params]} "
        f"frozen={list(family.shared_arrays)}"
    )

    space = make_space()  # derived from the registry — IVF_PQR included
    assert "IVF_PQR" in space.type_names, "registry-derived space must expose the new family"
    print(f"   derived space: {space.dims} dims over {len(space.type_names)} families")

    print("== static tuning: IVF_PQR vs two built-ins (12 iters, analytic) ==")
    ds = make_dataset("glove_like", n=3072, n_queries=64, k=10, seed=0)
    env = VDMSTuningEnv(ds, mode="analytic", seed=0)
    sub = make_space(include=("IVF_PQ", "SCANN", "IVF_PQR"))
    tuner = VDTuner(sub, env, seed=0)
    TuningSession(tuner).run(12)
    front = pareto_front(tuner.Y)
    front_types = sorted({c["index_type"] for c in tuner.pareto_configs()})
    print(f"   Pareto front ({len(front)} points) from families: {front_types}")
    for spd, rec in front:
        print(f"     qps={spd:9.0f}  recall={rec:.3f}")

    print("== streaming replay: seals + frozen PQ codebooks ==")
    trace = make_trace("glove_like", n_base=1024, n_ops=300, seed=0, mix=(0.3, 0.6, 0.1))
    cfg = dict(sub.default_config("IVF_PQR"), segment_max_size=512, seal_proportion=0.5)
    r = replay_trace(trace, cfg, seed=0, mode="analytic")
    print(
        f"   sustained replay: qps={r['speed']:.0f} recall={r['recall']:.3f} "
        f"seals={r['n_seals']:.0f} compactions={r['n_compactions']:.0f}"
    )
    if r["n_seals"] < 1:
        print("   FAIL: streaming replay never sealed a segment", file=sys.stderr)
        return 1
    if not (tuner.Y[:, 1] > 0.2).any():
        print("   FAIL: tuned configurations never retrieved anything", file=sys.stderr)
        return 1
    print("   custom family tuned end-to-end with zero core edits")
    return 0


if __name__ == "__main__":
    sys.exit(main())
